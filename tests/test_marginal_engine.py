"""MarginalEngine: compile-once serving of measure/reconstruct traffic."""
import numpy as np
import pytest

import jax

from repro.core import (Domain, MarginalWorkload, exact_marginals_from_x,
                        measure, reconstruct_all, select_sum_of_variances)
from repro.engine import MarginalEngine
from repro.kernels.kron_matvec.stats import chain_stats, reset_chain_stats


def _setup(rng, sizes=(3, 4, 2, 4), cliques=((0, 1), (1, 2), (2, 3), (0, 3)),
           budget=20.0):
    dom = Domain.create(list(sizes))
    wk = MarginalWorkload(dom, tuple(cliques))
    plan = select_sum_of_variances(wk, budget)
    x = rng.integers(0, 9, dom.universe_size()).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    return plan, margs, x


def test_engine_matches_plain_pipeline(rng):
    plan, margs, _ = _setup(rng)
    key = jax.random.PRNGKey(5)
    eng = MarginalEngine(plan, use_kernel=True)
    tables, meas = eng.release(margs, key)
    ref_meas = measure(plan, margs, key, use_kernel=False, batched=False)
    ref_tables = reconstruct_all(plan, ref_meas)
    for c in plan.cliques:
        assert np.allclose(meas[c].omega, ref_meas[c].omega, atol=1e-3), c
    for c in plan.workload.cliques:
        scale = max(np.abs(ref_tables[c]).max(), 1.0)
        assert np.max(np.abs(tables[c] - ref_tables[c])) / scale < 2e-4, c


def test_engine_use_kernel_auto_resolves_per_backend(rng):
    """Default None → batched jnp off-TPU: serving issues no pallas_calls."""
    plan, margs, _ = _setup(rng)
    eng = MarginalEngine(plan)
    assert eng.use_kernel is (jax.default_backend() == "tpu")
    if not eng.use_kernel:
        reset_chain_stats()
        eng.release(margs, jax.random.PRNGKey(0))
        assert chain_stats()["pallas_calls"] == 0


def test_engine_precompiles_every_chain(rng):
    plan, margs, _ = _setup(rng)
    eng = MarginalEngine(plan, use_kernel=True, precompile=True)
    assert eng.stats.compile_warmups == len(eng.chain_plans()) > 0
    assert eng.stats.measure_signatures < len(plan.cliques)   # batching is real
    for row in eng.chain_plans():
        assert row["fused"]
        assert row["w_in"] % 128 == 0 and row["batch_padded"] % 8 == 0


def test_engine_serving_reuses_compiled_chains(rng):
    """After warmup, serving N requests issues exactly N× the per-request
    chain count — no per-clique explosion, no recompile-driven extra calls."""
    plan, margs, _ = _setup(rng)
    eng = MarginalEngine(plan, use_kernel=True)
    n_measure = sum(1 for d in eng._measure_groups if d)
    n_rec = sum(1 for d in eng._reconstruct_groups if d)
    reset_chain_stats()
    for i in range(3):
        tables, _ = eng.release(margs, jax.random.PRNGKey(i))
    st = chain_stats()
    assert st["pallas_calls"] == 3 * (n_measure + n_rec)
    assert st["fallback_chains"] == 0
    assert eng.stats.measure_calls == 3 and eng.stats.reconstruct_calls == 3


def test_engine_unbiased_within_variance(rng):
    plan, margs, x = _setup(rng, budget=200.0)
    eng = MarginalEngine(plan)
    tables, _ = eng.release(margs, jax.random.PRNGKey(9))
    for c in plan.workload.cliques:
        truth = exact_marginals_from_x(plan.domain, [c], x)[c]
        sd = np.sqrt(plan.marginal_variance(c))
        assert np.all(np.abs(tables[c] - truth) < 6 * sd + 1e-6), c


def test_engine_jnp_mode_and_reconstruct_subset(rng):
    plan, margs, _ = _setup(rng)
    eng = MarginalEngine(plan, use_kernel=False)
    meas = eng.measure(margs, jax.random.PRNGKey(2))
    only = [(0, 1)]
    tables = eng.reconstruct(meas, cliques=only)
    assert set(tables) == {(0, 1)}
    assert tables[(0, 1)].shape == (12,)
    assert eng.variances()[(0, 1)] == pytest.approx(
        plan.marginal_variance((0, 1)))


def test_engine_single_attribute_domain(rng):
    dom = Domain.create([5])
    wk = MarginalWorkload(dom, ((0,),))
    plan = select_sum_of_variances(wk, 10.0)
    margs = {(): np.array([9.0]), (0,): rng.integers(0, 5, 5).astype(float)}
    eng = MarginalEngine(plan)
    tables, _ = eng.release(margs, jax.random.PRNGKey(0))
    assert tables[(0,)].shape == (5,)
