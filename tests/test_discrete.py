"""Discrete Gaussian (Section 5): sampler exactness, Alg 3 equivalence,
privacy accounting (Thm 6), and the Example-2 naive blow-up."""
import math
import random
from fractions import Fraction

import numpy as np
import pytest

from repro.core import Domain, MarginalWorkload, select_sum_of_variances
from repro.core.discrete import (discrete_zcdp_rho, measure_discrete,
                                 naive_discrete_rho, rationalize_sigma,
                                 sample_discrete_gaussian,
                                 xi_l2_sensitivity2)
from repro.core.kron import kron_expand, kron_matvec_np
from repro.core.mechanism import exact_marginals_from_x
from repro.core.residual import p_coeff, sub_gram, sub_matrix
from repro.core.reconstruct import reconstruct_marginal


def test_sampler_moments():
    rng = random.Random(0)
    for s2 in (Fraction(1), Fraction(4), Fraction(25, 4)):
        xs = np.array([sample_discrete_gaussian(s2, rng) for _ in range(3000)],
                      dtype=float)
        assert abs(xs.mean()) < 4 * math.sqrt(float(s2) / 3000)
        assert xs.var() <= float(s2) * 1.15          # var(N_Z) ≤ σ²
        assert xs.var() >= float(s2) * 0.75


def test_sampler_integer_support():
    rng = random.Random(1)
    xs = [sample_discrete_gaussian(Fraction(9, 4), rng) for _ in range(200)]
    assert all(isinstance(x, int) for x in xs)


def test_sampler_big_gamma2_no_overflow():
    """Regression (isqrt fix): γ² at Πn_i = 10²⁰ scale — and beyond float64
    range entirely — samples fine; ``math.sqrt(float(σ²))`` raised
    OverflowError (or silently lost precision) here."""
    rng = random.Random(0)
    g2 = Fraction(17 * 10 ** 40, 4)               # Πn_i = 10²⁰ scale
    xs = [sample_discrete_gaussian(g2, rng) for _ in range(5)]
    assert all(isinstance(x, int) for x in xs)
    assert any(abs(x) > 10 ** 19 for x in xs)     # σ ≈ 2·10²⁰: not degenerate
    g2_huge = Fraction(10 ** 320, 7)              # float(g2_huge) overflows
    with pytest.raises(OverflowError):
        float(g2_huge)
    x = sample_discrete_gaussian(g2_huge, rng)
    assert isinstance(x, int)


def test_rationalize_rounds_up():
    for s in (0.3333, 1.4142, 2.7182):
        sb = rationalize_sigma(s, digits=4)
        assert float(sb) >= s
        assert float(sb) - s < 1e-4 + 1e-12


def test_alg3_matrix_identities():
    """Y†Ξ = R_A and the continuous version of Alg 3 has cov σ̄²Σ_A (Thm 6)."""
    dom = Domain.create([4, 3])
    clique = (0, 1)
    H = kron_expand([4 * np.eye(4) - np.ones((4, 4)),
                     3 * np.eye(3) - np.ones((3, 3))])
    Ypinv = kron_expand([sub_matrix(4) / 4, sub_matrix(3) / 3])
    R = kron_expand([sub_matrix(4), sub_matrix(3)])
    # Y† H = R  (applied to the marginal table)
    assert np.allclose(Ypinv @ H, R @ np.eye(12), atol=1e-9)
    # covariance: Y† (γ² I) Y†ᵀ = σ̄² Σ_A  with γ² = σ̄²·(4·3)²
    gamma2 = 12.0 ** 2
    cov = gamma2 * Ypinv @ Ypinv.T
    Sigma = kron_expand([sub_gram(4), sub_gram(3)])
    assert np.allclose(cov, Sigma, atol=1e-8)


def test_thm6_rho_equals_continuous():
    dom = Domain.create([2, 2, 2])
    for clique in [(0,), (0, 1), (0, 1, 2)]:
        sb = Fraction(2, 3)
        rho_disc = discrete_zcdp_rho(dom, clique, sb)
        rho_cont = Fraction(1, 2) * Fraction(
            int(round(p_coeff(dom, clique) * 2 ** len(clique))),
            2 ** len(clique)) / sb ** 2
        assert rho_disc == rho_cont


def test_example2_blowup():
    """Naive discrete swap loses exactly 2^k on k binary attributes."""
    dom = Domain.create([2] * 3)
    wk = MarginalWorkload(dom, ((0, 1, 2),))
    plan = select_sum_of_variances(wk, 1.0)
    # restrict attention to the top clique
    k = 3
    sigma2 = plan.sigmas[(0, 1, 2)]
    rho_cont = p_coeff(dom, (0, 1, 2)) / (2 * sigma2)     # (1/2)·2^-k/σ²...
    rho_naive = 1.0 / (2 * sigma2)
    assert math.isclose(rho_naive / rho_cont, 2 ** k, rel_tol=1e-9)
    assert naive_discrete_rho(plan) > sum(
        p_coeff(dom, c) / (2 * plan.sigmas[c]) for c in plan.cliques)


def test_measure_discrete_end_to_end(rng):
    dom = Domain.create([3, 2])
    wk = MarginalWorkload(dom, ((0, 1),))
    plan = select_sum_of_variances(wk, 0.5)
    x = rng.integers(0, 30, 6).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    # zero-noise override: must reproduce exact residual answers
    zero = lambda g2, n, r: np.zeros(n, dtype=object)
    meas = measure_discrete(plan, margs, random.Random(0), _noise_override=zero)
    got = reconstruct_marginal(plan, meas, (0, 1))
    assert np.allclose(got, margs[(0, 1)], atol=1e-8)
    # real noise: unbiased-ish, integer-combination structure
    meas = measure_discrete(plan, margs, random.Random(0))
    got = reconstruct_marginal(plan, meas, (0, 1))
    assert got.shape == (6,)
    assert np.all(np.isfinite(got))
