"""Release server: cross-tenant batching, budget enforcement, warm pool.

The kernel-launch-counter test follows the PR-4 hot-path-flag style: patch
the chain-launch entry point the fused path uses and count invocations — two
same-signature tenants served in one batch must cost exactly as many chain
launches as one tenant alone.
"""
import json
import os
import urllib.error
import urllib.request

import numpy as np
import pytest

import jax

from repro.core import Domain, all_kway, select
from repro.core.accountant import BudgetExhausted
from repro.core.mechanism import measure, pcost_of_plan
from repro.data.tabular import marginals_from_records, synthetic_records
from repro.engine import multi as multi_mod
from repro.engine.multi import can_fuse, measure_multi
from repro.serve import (BudgetLedger, EnginePool, ReleaseRequest,
                         ReleaseServer, start_stats_http)

DOM = Domain.create([5, 5, 5])          # uniform sizes -> 2 chain signatures


def _tenant_setup(n_tenants, n_records=2000):
    wk = all_kway(DOM, 2, include_lower=True)
    plans, margs = [], []
    for t in range(n_tenants):
        plan = select(wk, pcost_budget=1.0)
        plans.append(plan)
        recs = synthetic_records(DOM, n_records, seed=t)
        margs.append(marginals_from_records(DOM, plan.cliques, recs))
    return plans, margs


def _server(tmp_path, plans, rho=100.0, **kw):
    ledger = BudgetLedger(os.path.join(str(tmp_path), "ledger.jsonl"),
                          fsync=False)
    srv = ReleaseServer(ledger, **kw).start()
    for i, plan in enumerate(plans):
        srv.register_tenant(f"t{i}", plan, rho=rho)
    return srv


# ------------------------------------------------------------- measure_multi
def test_measure_multi_bit_exact_vs_per_request():
    plans, margs = _tenant_setup(3)
    keys = [jax.random.PRNGKey(i) for i in range(3)]
    seq = [measure(p, m, k) for p, m, k in zip(plans, margs, keys)]
    fused = measure_multi(list(zip(plans, margs, keys)))
    for s, f in zip(seq, fused):
        assert set(s) == set(f)
        for c in s:
            assert np.array_equal(s[c].omega, f[c].omega), c
            assert s[c].sigma2 == f[c].sigma2


def test_measure_multi_rejects_unfusable_plans():
    from repro.core.plus import PlusSchema, select_plus
    dom = Domain.create([6, 4], kinds=["numeric", "categorical"])
    wk = all_kway(dom, 2, include_lower=True)
    schema = PlusSchema.create(dom, ["range", "identity"],
                               strategy_mode="hier")
    pp = select_plus(wk, schema, pcost_budget=1.0)
    assert not can_fuse(pp)
    recs = synthetic_records(dom, 500, seed=0)
    margs = marginals_from_records(dom, pp.cliques, recs)
    with pytest.raises(ValueError, match="plain marginal plans"):
        measure_multi([(pp, margs, jax.random.PRNGKey(0))])


def test_cross_tenant_batching_shares_chain_launches(tmp_path, monkeypatch):
    """Two same-signature tenants in one batch ride the SAME chain launches
    (kernel-launch counter): fused launches == launches for one tenant."""
    calls = {"n": 0}
    real = multi_mod.kron_matvec_batched

    def counting(factors, x, dims):
        calls["n"] += 1
        return real(factors, x, dims)

    monkeypatch.setattr(multi_mod, "kron_matvec_batched", counting)

    plans, margs = _tenant_setup(2)
    keys = [jax.random.PRNGKey(7), jax.random.PRNGKey(8)]

    calls["n"] = 0
    measure_multi([(plans[0], margs[0], keys[0])])
    solo_launches = calls["n"]
    assert solo_launches == 2            # signatures (5,) and (5,5)

    calls["n"] = 0
    measure_multi(list(zip(plans, margs, keys)))
    assert calls["n"] == solo_launches   # second tenant rides along free

    # ... and through the server: one paused batch, two tenants, no extra
    # launches beyond the solo count.
    srv = _server(tmp_path, plans, max_batch=8, max_wait_ms=1.0)
    try:
        srv.pause()
        futs = [srv.submit(ReleaseRequest(tenant=f"t{i}", marginals=margs[i],
                                          seed=i))
                for i in range(2)]
        calls["n"] = 0
        srv.resume()
        res = [f.result(120) for f in futs]
        assert calls["n"] == solo_launches
        assert all(r.batched for r in res)
        assert all(r.batch_size == 2 for r in res)
    finally:
        srv.stop()
        srv.ledger.close()


def test_server_sequential_and_batched_bit_identical(tmp_path):
    plans, margs = _tenant_setup(3)

    def run(max_batch):
        srv = _server(tmp_path.joinpath(f"b{max_batch}"), plans,
                      max_batch=max_batch)
        try:
            srv.pause()
            futs = [srv.submit(ReleaseRequest(tenant=f"t{i}",
                                              marginals=margs[i], seed=40 + i))
                    for i in range(3)]
            srv.resume()
            return [f.result(120) for f in futs]
        finally:
            srv.stop()
            srv.ledger.close()

    os.makedirs(str(tmp_path / "b1"), exist_ok=True)
    os.makedirs(str(tmp_path / "b8"), exist_ok=True)
    seq, bat = run(1), run(8)
    assert not any(r.batched for r in seq)
    for a, b in zip(seq, bat):
        assert set(a.tables) == set(b.tables)
        for c in a.tables:
            assert np.array_equal(a.tables[c], b.tables[c])


# ------------------------------------------------------------------- budgets
def test_over_budget_rejection_carries_exact_remaining_rho(tmp_path):
    plans, margs = _tenant_setup(1)
    per_release = pcost_of_plan(plans[0])
    # budget fits exactly 2 releases plus half of one more
    total = 2.5 * per_release
    srv = _server(tmp_path, plans, rho=total / 2.0)
    try:
        for s in range(2):
            srv.request_sync(ReleaseRequest(tenant="t0", marginals=margs[0],
                                            seed=s))
        fut = srv.submit(ReleaseRequest(tenant="t0", marginals=margs[0]))
        with pytest.raises(BudgetExhausted) as ei:
            fut.result(120)
        err = ei.value
        assert err.tenant == "t0"
        assert err.requested_pcost == pytest.approx(per_release)
        assert err.remaining_pcost == pytest.approx(0.5 * per_release)
        assert err.remaining_rho == pytest.approx(0.25 * per_release)
        # rejection is pre-measure: ledger unchanged, later top-up would work
        assert srv.ledger.spent("t0") == pytest.approx(2 * per_release)
        st = srv.stats_dict()
        assert st["tenants"]["t0"]["rejected_budget"] == 1
        assert st["tenants"]["t0"]["completed"] == 2
    finally:
        srv.stop()
        srv.ledger.close()


def test_budget_is_per_tenant(tmp_path):
    plans, margs = _tenant_setup(2)
    per = pcost_of_plan(plans[0])
    srv = _server(tmp_path, plans, rho=per / 2.0)   # exactly 1 release each
    try:
        srv.request_sync(ReleaseRequest(tenant="t0", marginals=margs[0]))
        with pytest.raises(BudgetExhausted):
            srv.request_sync(ReleaseRequest(tenant="t0",
                                            marginals=margs[0]))
        # t0 exhausted, t1 unaffected
        r = srv.request_sync(ReleaseRequest(tenant="t1", marginals=margs[1]))
        assert r.pcost_charged == pytest.approx(per)
    finally:
        srv.stop()
        srv.ledger.close()


def test_malformed_marginals_rejected_before_charge(tmp_path):
    """Marginals with missing cliques or wrong cell counts fail in phase 1,
    BEFORE the ledger is charged — and the worker survives to serve the next
    (valid) request."""
    plans, margs = _tenant_setup(1)
    srv = _server(tmp_path, plans)
    try:
        missing = {c: v for c, v in margs[0].items() if len(c) != 2}
        with pytest.raises(ValueError, match="missing clique"):
            srv.request_sync(ReleaseRequest(tenant="t0", marginals=missing))
        bad_shape = dict(margs[0])
        some_pair = next(c for c in plans[0].cliques if len(c) == 2)
        bad_shape[some_pair] = np.zeros(3)
        with pytest.raises(ValueError, match="cells, want"):
            srv.request_sync(ReleaseRequest(tenant="t0",
                                            marginals=bad_shape))
        # neither malformed request burned any budget
        assert srv.ledger.spent("t0") == 0.0
        # worker alive and still serving: a valid request goes through
        r = srv.request_sync(ReleaseRequest(tenant="t0", marginals=margs[0]))
        assert r.tables is not None
        assert srv.ledger.spent("t0") == pytest.approx(r.pcost_charged)
        st = srv.stats_dict()
        assert st["tenants"]["t0"]["failed"] == 2
        assert st["tenants"]["t0"]["completed"] == 1
    finally:
        srv.stop()
        srv.ledger.close()


def test_worker_survives_fused_path_failure(tmp_path, monkeypatch):
    """An unexpected exception inside the fused measure_multi path must not
    kill the worker: charged requests fall back to the solo path and still
    resolve (bit-identical, since both paths draw the same noise)."""
    import repro.serve.server as server_mod

    def boom(items, use_kernel=False, dtype=None):
        raise RuntimeError("fused path exploded")

    plans, margs = _tenant_setup(2)
    srv = _server(tmp_path, plans, max_batch=8)
    try:
        monkeypatch.setattr(server_mod, "measure_multi", boom)
        srv.pause()
        futs = [srv.submit(ReleaseRequest(tenant=f"t{i}", marginals=margs[i],
                                          seed=90 + i))
                for i in range(2)]
        srv.resume()
        res = [f.result(120) for f in futs]
        assert not any(r.batched for r in res)     # solo fallback
        assert all(r.tables is not None for r in res)
        # worker alive; fused path restored serves the next batch normally
        monkeypatch.undo()
        r = srv.request_sync(ReleaseRequest(tenant="t0", marginals=margs[0],
                                            seed=90))
        for c in r.tables:
            assert np.array_equal(r.tables[c], res[0].tables[c])
    finally:
        srv.stop()
        srv.ledger.close()


def test_submit_after_stop_raises(tmp_path):
    plans, margs = _tenant_setup(1)
    srv = _server(tmp_path, plans)
    try:
        srv.request_sync(ReleaseRequest(tenant="t0", marginals=margs[0]))
    finally:
        srv.stop()
    with pytest.raises(RuntimeError, match="not running"):
        srv.submit(ReleaseRequest(tenant="t0", marginals=margs[0]))
    srv.ledger.close()


def test_register_tenant_mid_traffic(tmp_path):
    """Registering tenants while the worker serves traffic must not corrupt
    the shared engine pool or the session map (lock-guarded)."""
    plans, margs = _tenant_setup(4)
    srv = _server(tmp_path, plans[:1])
    errors = []

    def hammer():
        try:
            for s in range(10):
                srv.request_sync(ReleaseRequest(tenant="t0",
                                                marginals=margs[0], seed=s))
        except Exception as exc:       # noqa: BLE001 — surfaced below
            errors.append(exc)

    t = None
    try:
        import threading
        t = threading.Thread(target=hammer)
        t.start()
        for i in range(1, 4):
            srv.register_tenant(f"t{i}", plans[i], rho=100.0)
            srv.request_sync(ReleaseRequest(tenant=f"t{i}",
                                            marginals=margs[i]))
        t.join(120)
        assert not t.is_alive() and not errors
        assert set(srv.tenants()) == {"t0", "t1", "t2", "t3"}
        assert srv.stats_dict()["tenants"]["t0"]["completed"] == 10
    finally:
        if t is not None and t.is_alive():
            t.join(1)
        srv.stop()
        srv.ledger.close()


def test_unknown_tenant_and_bad_requests(tmp_path):
    plans, margs = _tenant_setup(1)
    srv = _server(tmp_path, plans)
    try:
        with pytest.raises(KeyError):
            srv.request_sync(ReleaseRequest(tenant="ghost",
                                            marginals=margs[0]))
        with pytest.raises(ValueError, match="needs marginals"):
            srv.request_sync(ReleaseRequest(tenant="t0"))
        with pytest.raises(ValueError, match="unknown request kind"):
            srv.request_sync(ReleaseRequest(tenant="t0", kind="nope",
                                            marginals=margs[0]))
        with pytest.raises(ValueError, match="RP\\+ plan"):
            srv.request_sync(ReleaseRequest(tenant="t0", kind="range",
                                            marginals=margs[0]))
        # failures consumed no budget
        assert srv.ledger.spent("t0") == 0.0
    finally:
        srv.stop()
        srv.ledger.close()


# ------------------------------------------------- postprocess + synthesis
def test_nonneg_release_then_synthesis_charges_nothing(tmp_path):
    plans, margs = _tenant_setup(1)
    srv = _server(tmp_path, plans)
    try:
        with pytest.raises(ValueError, match="non-negative release"):
            srv.request_sync(ReleaseRequest(tenant="t0", kind="synthesis",
                                            n_records=50))
        r = srv.request_sync(ReleaseRequest(tenant="t0", marginals=margs[0],
                                            postprocess="nonneg"))
        assert all(tab.min() >= 0 for tab in r.tables.values())
        spent = srv.ledger.spent("t0")
        s = srv.request_sync(ReleaseRequest(tenant="t0", kind="synthesis",
                                            n_records=200, seed=3))
        assert s.records.shape == (200, DOM.n_attrs)
        assert s.pcost_charged == 0.0
        assert srv.ledger.spent("t0") == spent   # synthesis is postprocessing
    finally:
        srv.stop()
        srv.ledger.close()


# ------------------------------------------------------------- stats + http
def test_stats_dict_and_http_endpoint(tmp_path):
    plans, margs = _tenant_setup(2)
    srv = _server(tmp_path, plans, max_batch=8)
    httpd = None
    try:
        srv.pause()
        futs = [srv.submit(ReleaseRequest(tenant=f"t{i}", marginals=margs[i]))
                for i in range(2)]
        srv.resume()
        [f.result(120) for f in futs]
        st = srv.stats_dict()
        assert st["requests_total"] == 2
        assert st["batch_occupancy"] == pytest.approx(2.0)
        assert st["tenants"]["t0"]["p50_ms"] is not None
        assert st["engine_cache"]["hit_rate"] is not None
        assert st["ledger"]["t0"]["charges"] == 1

        httpd, port = start_stats_http(srv)
        base = f"http://127.0.0.1:{port}"
        health = json.load(urllib.request.urlopen(f"{base}/healthz"))
        assert health["ok"] and set(health["tenants"]) == {"t0", "t1"}
        remote = json.load(urllib.request.urlopen(f"{base}/stats"))
        assert remote["requests_total"] == 2
        ledger = json.load(urllib.request.urlopen(f"{base}/ledger"))
        assert ledger["t1"]["charges"] == 1
    finally:
        if httpd is not None:
            httpd.shutdown()
        srv.stop()
        srv.ledger.close()


def test_healthz_reports_dead_worker_with_503(tmp_path):
    """/healthz is a liveness probe: 200 + ok while the worker thread runs,
    503 + ok=False once it is gone — the same condition submit() refuses on."""
    plans, margs = _tenant_setup(1)
    srv = _server(tmp_path, plans)
    httpd = None
    try:
        srv.request_sync(ReleaseRequest(tenant="t0", marginals=margs[0]))
        httpd, port = start_stats_http(srv)
        base = f"http://127.0.0.1:{port}"
        health = json.load(urllib.request.urlopen(f"{base}/healthz"))
        assert health["ok"] and health["worker_alive"]
        assert health["queue_depth"] == 0
        assert health["uptime_s"] >= 0
        srv.stop()                          # worker dead, HTTP still up
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{base}/healthz")
        assert ei.value.code == 503
        body = json.load(ei.value)
        assert body["ok"] is False and body["worker_alive"] is False
    finally:
        if httpd is not None:
            httpd.shutdown()
        srv.stop()
        srv.ledger.close()


def test_trace_id_propagates_serve_to_kernel(tmp_path):
    """One traced request yields ONE connected span tree: the trace ID minted
    at submit() reaches the kernel.chain spans inside the fused launch, and
    every span's parent is another span of the same trace."""
    from repro.obs import TRACER
    plans, margs = _tenant_setup(2)
    TRACER.enable()                         # in-memory ring, no file sink
    TRACER.drain()
    try:
        srv = _server(tmp_path, plans, max_batch=8, use_kernel=True)
        try:
            srv.pause()
            futs = [srv.submit(ReleaseRequest(tenant=f"t{i}",
                                              marginals=margs[i], seed=i))
                    for i in range(2)]
            srv.resume()
            res = [f.result(300) for f in futs]
            assert all(r.batched for r in res)
        finally:
            srv.stop()
            srv.ledger.close()
        spans = TRACER.drain()
    finally:
        TRACER.disable()

    by_trace = {}
    for s in spans:
        by_trace.setdefault(s.trace_id, []).append(s)
    roots = [s for s in spans if s.name == "serve.request"]
    assert len(roots) == 2                  # one root per request
    assert len({r.trace_id for r in roots}) == 2
    for root in roots:
        tree = by_trace[root.trace_id]
        ids = {s.span_id for s in tree}
        orphans = [s for s in tree
                   if s.parent_id is not None and s.parent_id not in ids]
        assert not orphans                  # fully connected tree
        names = {s.name for s in tree}
        assert {"serve.request", "serve.queue_wait", "serve.charge",
                "serve.fuse"} <= names
        assert root.attrs["outcome"] == "completed"
    # the fused launch's kernel spans ride the batch leader's trace
    kernel_spans = [s for s in spans if s.name == "kernel.chain"]
    assert kernel_spans
    assert all(s.trace_id in by_trace for s in kernel_spans)
    leader = [s for s in spans if s.name == "serve.fuse"
              and not s.attrs.get("shared")]
    assert leader and any(s.trace_id == leader[0].trace_id
                          and s.attrs.get("fused") is not None
                          for s in kernel_spans)


def test_metrics_endpoint_parseable_under_concurrent_traffic(tmp_path):
    """16 threads of mixed traffic + /metrics scrapes: every scrape parses,
    and the final exposition agrees with /stats (one backing store)."""
    from repro.obs import parse_exposition
    plans, margs = _tenant_setup(4)
    srv = _server(tmp_path, plans, max_batch=8)
    httpd = None
    errors = []
    try:
        httpd, port = start_stats_http(srv)
        base = f"http://127.0.0.1:{port}"

        def submit(i):
            try:
                for s in range(3):
                    srv.request_sync(ReleaseRequest(
                        tenant=f"t{i % 4}", marginals=margs[i % 4],
                        seed=100 * i + s))
            except Exception as exc:       # noqa: BLE001 — surfaced below
                errors.append(exc)

        def scrape():
            try:
                for _ in range(10):
                    with urllib.request.urlopen(f"{base}/metrics") as resp:
                        assert resp.headers["Content-Type"].startswith(
                            "text/plain; version=0.0.4")
                        parse_exposition(resp.read().decode())
            except Exception as exc:       # noqa: BLE001 — surfaced below
                errors.append(exc)

        import threading
        threads = ([threading.Thread(target=submit, args=(i,))
                    for i in range(8)]
                   + [threading.Thread(target=scrape) for _ in range(8)])
        for t in threads:
            t.start()
        for t in threads:
            t.join(300)
        assert not any(t.is_alive() for t in threads)
        assert not errors, errors[:3]

        # /metrics and /stats read the same store -> identical numbers
        with urllib.request.urlopen(f"{base}/metrics") as resp:
            parsed = parse_exposition(resp.read().decode())
        st = srv.stats_dict()
        req = parsed["repro_serve_requests_total"]
        for tname, tstats in st["tenants"].items():
            assert req.get(f'tenant="{tname}",outcome="completed"',
                           0) == tstats["completed"]
        assert parsed["repro_serve_batches_total"][""] == st["batches"]
        for tname, led in st["ledger"].items():
            assert parsed["repro_ledger_charges_total"][
                f'tenant="{tname}"'] == led["charges"]
            assert parsed["repro_ledger_pcost_spent"][
                f'tenant="{tname}"'] == pytest.approx(led["pcost_spent"])
    finally:
        if httpd is not None:
            httpd.shutdown()
        srv.stop()
        srv.ledger.close()


# ---------------------------------------------------------------- warm pool
def test_engine_pool_caches_and_counts(tmp_path):
    plans, _ = _tenant_setup(2)
    pool = EnginePool(maxsize=4)
    e0 = pool.engine_for("a", plans[0])
    assert pool.engine_for("a", plans[0]) is e0       # hit
    assert pool.engine_for("b", plans[0]) is e0       # cross-tenant hit
    assert pool.engine_for("b", plans[1]) is not e0
    s = pool.stats()
    assert s["hits"] == 2 and s["misses"] == 2 and s["entries"] == 2


def test_engine_pool_pins_hot_and_evicts_cold():
    wk_a = all_kway(Domain.create([4, 3]), 2, include_lower=True)
    plans = [select(all_kway(DOM, 2, include_lower=True), pcost_budget=1.0)
             for _ in range(3)] + [select(wk_a, pcost_budget=1.0)]
    pool = EnginePool(maxsize=2, pin_count=1)
    hot = pool.engine_for("a", plans[0])
    for _ in range(5):                       # "a" hammers plan 0 -> hot, pinned
        pool.engine_for("a", plans[0])
    assert len(pool.cache._pinned) == 1
    pool.engine_for("b", plans[1])           # fills the cache
    pool.engine_for("c", plans[2])           # evicts ... someone unpinned
    pool.engine_for("d", plans[3])
    assert pool.cache.evictions == 2
    assert pool.engine_for("a", plans[0]) is hot      # hot engine survived
    assert pool.stats()["snapshot"]          # snapshot renders


def test_engine_cache_weighted_eviction_prefers_low_score():
    from repro.engine.sharded import _EngineCache
    import jax.numpy as jnp

    class _P:                                # minimal plan stand-in
        def engine(self, **kw):
            raise AssertionError("not used")

    cache = _EngineCache(maxsize=2)
    p1, p2, p3 = _P(), _P(), _P()
    cache.put(p1, False, jnp.float32, "e1")
    cache.put(p2, False, jnp.float32, "e2")
    scores = {cache._key(p1, False, jnp.float32): 5.0,
              cache._key(p2, False, jnp.float32): 1.0}
    cache.evict_score = lambda k: scores.get(k, 0.0)
    cache.put(p3, False, jnp.float32, "e3")  # evicts p2 (lowest score)
    assert cache.get(p1, False, jnp.float32) == "e1"
    assert cache.get(p2, False, jnp.float32) is None
    assert cache.evictions == 1


def test_engine_cache_pinned_entry_survives_lru():
    from repro.engine.sharded import _EngineCache
    import jax.numpy as jnp

    class _P:
        def engine(self, **kw):
            raise AssertionError("not used")

    cache = _EngineCache(maxsize=2)
    keep, other, third = _P(), _P(), _P()
    cache.put(keep, False, jnp.float32, "keep")
    cache.pin(keep, False, jnp.float32)
    cache.put(other, False, jnp.float32, "other")
    cache.put(third, False, jnp.float32, "third")   # LRU would evict "keep"
    assert cache.get(keep, False, jnp.float32) == "keep"
    assert cache.get(other, False, jnp.float32) is None
    # all-pinned cache still makes room (advisory pins)
    cache.pin(third, False, jnp.float32)
    fourth = _P()
    cache.put(fourth, False, jnp.float32, "fourth")
    assert cache.forced_evictions == 1
