"""Training substrate: optimizer (incl. int8 states), train loop convergence,
checkpoint/restart, DP-SGD clipping + accounting, MoE fallback routing."""
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.shapes import reduced_config
from repro.models import Model
from repro.train import (AdamWConfig, DPSGDConfig, TrainState, apply_updates,
                         init_opt_state, make_train_step)
from repro.train.checkpoint import CheckpointManager
from repro.train.dp import DPSGDAccountant, per_example_clipped_grad
from repro.train.optimizer import dequantize_i8, quantize_i8
from repro.train.train_step import init_train_state
from repro.data.tokens import synthetic_lm_batches


def test_int8_quant_roundtrip(rng):
    for shape in [(4, 256), (3, 5, 128), (7,), (2, 100)]:
        x = rng.standard_normal(shape).astype(np.float32)
        q, s = quantize_i8(jnp.asarray(x))
        back = np.asarray(dequantize_i8(q, s))
        blockmax = np.abs(x).max()
        assert np.max(np.abs(back - x)) <= blockmax / 127.0 + 1e-7


def test_adamw_matches_reference(rng):
    params = {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)}
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9, warmup_steps=1)
    state = init_opt_state(params, cfg)
    new_p, new_s, m = apply_updates(params, grads, state, cfg)
    g = np.asarray(grads["w"])
    mh = 0.1 * g / (1 - 0.9)
    vh = 0.001 * g * g / (1 - 0.999)
    want = np.asarray(params["w"]) - 1e-2 * mh / (np.sqrt(vh) + cfg.eps)
    assert np.allclose(np.asarray(new_p["w"]), want, atol=1e-6)


def test_train_loss_decreases():
    cfg = reduced_config("qwen3-4b")
    model = Model(cfg)
    oc = AdamWConfig(lr=3e-3, warmup_steps=5)
    state = init_train_state(model, jax.random.PRNGKey(0), oc)
    step = jax.jit(make_train_step(model, oc, microbatches=2, remat=False))
    gen = synthetic_lm_batches(cfg.vocab_size, batch=8, seq_len=16, seed=0)
    losses = []
    b0 = next(gen)
    batch = {"tokens": jnp.asarray(b0["tokens"]),
             "labels": jnp.asarray(b0["labels"])}
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::10]


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg = reduced_config("qwen3-4b")
    model = Model(cfg)
    oc = AdamWConfig()
    state = init_train_state(model, jax.random.PRNGKey(0), oc)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(3, state, {"arch": cfg.name})
    mgr.save(7, state, {"arch": cfg.name}, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [3, 7]
    restored, manifest = mgr.restore(state)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert np.allclose(np.asarray(a, np.float32),
                           np.asarray(b, np.float32), atol=0)


def test_checkpoint_atomic_no_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((4,))}
    mgr.save(1, state)
    # a stale tmp dir must never be listed as a checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.all_steps() == [1]


def test_dp_per_example_clipping():
    cfg = reduced_config("qwen3-4b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (4, 8), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 8), 0, cfg.vocab_size)}
    C = 0.1
    g = per_example_clipped_grad(
        lambda p, b: model.loss_fn(p, b, remat=False), params, batch, C)
    norm = float(jnp.sqrt(sum(jnp.sum(jnp.square(x))
                              for x in jax.tree_util.tree_leaves(g))))
    assert norm <= C + 1e-5          # mean of ≤C-norm vectors has norm ≤ C


def test_dp_accountant_matches_core():
    from repro.core.accountant import zcdp_rho
    cfg = DPSGDConfig(clip_norm=1.0, noise_multiplier=2.0)
    acc = DPSGDAccountant(cfg)
    for _ in range(100):
        acc.charge_step()
    rep = acc.report()
    assert np.isclose(rep["pcost"], 100 / 4.0)
    assert np.isclose(rep["rho_zcdp"], zcdp_rho(25.0))
    assert rep["eps_at_delta_1e-6"] > 0


def test_moe_dense_fallback_routing():
    cfg = reduced_config("kimi-k2-1t-a32b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    l1 = float(model.loss_fn(params, batch, remat=False))
    assert np.isfinite(l1)
