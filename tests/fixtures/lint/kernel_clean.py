"""KN clean fixture: aligned literals, float32 noise, pure kernel bodies.

Must produce ZERO findings (tests/test_analysis.py asserts emptiness).
"""
import jax
import jax.numpy as jnp


def build_aligned():
    # multiple of the bfloat16 sublane quantum (16), budget under 32 MiB
    return plan_chain(shapes, block_l=64, dtype="bfloat16",
                      vmem_budget=16 * 1024 * 1024)


def sample_fp32(mats, x, key):
    z = jax.random.normal(key, (8,))
    y = fused_chain_matvec(mats, x, allow_narrow=False)
    return y + z


def reconstruct_narrow(mats, x):
    # narrow chain is fine here: no noise is drawn in this function
    return fused_chain_matvec(mats, x, allow_narrow=True)


@jax.jit
def jitted_pure(x):
    return jnp.tanh(x) * 2.0


def make_clean_kernel():
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    return pl.pallas_call(
        kernel, grid=(1,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, 0))])
