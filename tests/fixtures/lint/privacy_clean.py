"""PF001 clean fixture: every release path is sanitized or declassified.

Must produce ZERO findings (tests/test_analysis.py asserts emptiness).
"""


def resolve_measured(fut, engine, records, key):
    noisy = engine.measure(records, key)            # sanitizer: taint stops
    fut.set_result(noisy)


def resolve_metadata(fut, req):
    fut.set_result({"n": len(req.marginals),        # declassifier call
                    "shape": req.marginals[0].shape})  # declassifier attr


def construct_release(engine, req, key):
    return ReleaseResult(values=engine.measure(req.marginals, key))
