"""LK fixture: guarded-by violations.

LK001 twice (direct unguarded field access; call to a requires-lock helper
without the lock) and LK002 once (annotation names a lock the class never
creates).  Line numbers are asserted by tests/test_analysis.py.
"""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0                          # guarded-by: _lock

    def bump_unlocked(self):
        self._n += 1                         # line 16: LK001

    def _drain(self):  # requires-lock: _lock
        self._n = 0

    def reset_unlocked(self):
        self._drain()                        # line 22: LK001 (caller side)


class Phantom:
    def __init__(self):
        self._items = []                     # guarded-by: _missing  -> LK002
