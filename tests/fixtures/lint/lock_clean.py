"""LK clean fixture: every guarded access holds the lock.

Must produce ZERO findings (tests/test_analysis.py asserts emptiness).
"""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0                          # guarded-by: _lock

    def bump(self):
        with self._lock:
            self._n += 1

    def value(self):
        with self._lock:
            return self._n

    def _drain(self):  # requires-lock: _lock
        self._n = 0

    def reset(self):
        with self._lock:
            self._drain()
