"""PF001 fixture: raw taint reaches release sinks without a sanitizer.

Exercises: source call, source attribute, source parameter, taint through
arithmetic/comprehensions, and the ReleaseResult constructor sink.
Expected findings are asserted by tests/test_analysis.py — keep line
numbers stable when editing.
"""


def resolve_raw_histogram(fut, records):            # `records` is a source param
    hist = exact_marginals_from_x(records)
    fut.set_result(hist)                            # line 12: PF001


def resolve_request_payload(fut, req):
    payload = [m * 2 for m in req.marginals]        # source attr, comp taint
    fut.set_result(payload)                         # line 17: PF001


def construct_release(req):
    return ReleaseResult(values=req.marginals)      # line 21: PF001
