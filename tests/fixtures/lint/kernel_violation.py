"""KN fixture: one violation per kernel-invariant rule.

KN001 (bad block_l), KN002 (vmem_budget over the device ceiling), KN003
(allow_narrow in a noise-drawing function), KN004 (host RNG inside a jitted
body; print inside a pallas kernel body), KN005 (BlockSpec minor dim not
lane-aligned).  Line numbers are asserted by tests/test_analysis.py.
"""
import jax
import numpy as np


def build_bad_block():
    return plan_chain(shapes, block_l=12, dtype="float32")      # KN001


def build_bad_budget():
    return plan_chain(shapes, vmem_budget=64 * 1024 * 1024)     # KN002


def sample_with_narrow_chain(mats, x, key):
    z = jax.random.normal(key, (8,))
    y = fused_chain_matvec(mats, x, allow_narrow=True)          # KN003
    return y + z


@jax.jit
def jitted_with_host_rng(x):
    seed = np.random.normal()                                   # KN004
    return x * seed


def make_noisy_kernel():
    def kernel(x_ref, o_ref):
        print("debug")                                          # KN004
        o_ref[...] = x_ref[...]

    return pl.pallas_call(
        kernel, grid=(1,),
        in_specs=[pl.BlockSpec((8, 100), lambda i: (0, 0))])    # KN005
