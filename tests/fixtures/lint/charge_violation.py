# repro-lint: scope=serve
"""PF002 fixture: measurement not dominated by a ledger charge.

The module pragma above opts this file into serve-scope rules even though
it lives under tests/fixtures/.  ``Worker.serve_uncharged`` measures with
no charge anywhere on the path; ``Worker.serve_charged`` shows the clean
protocol (charge earlier in the same method) and must NOT fire.
"""


class Worker:
    def serve_uncharged(self, engine, req, key):
        return engine.measure(req.marginals, key)   # line 13: PF002

    def serve_charged(self, engine, req, key):
        self.ledger.charge(req.tenant, req.cost)
        return engine.measure(req.marginals, key)   # charged above: clean

    def batch(self, engine, pending, key):
        self.ledger.charge("t", 1.0)
        for req in pending:
            self._serve_one(engine, req, key)

    def _serve_one(self, engine, req, key):
        # every intra-class caller (batch) charges first: clean
        return engine.measure(req.marginals, key)
