"""Docs stay navigable: no broken intra-repo links in README.md / docs/*.md.

Runs the same checker CI's docs job runs (tools/check_doc_links.py), so a
broken link fails locally before it fails in CI.
"""
import glob
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

from check_doc_links import check_file, github_slug, main  # noqa: E402


def test_github_slug_rules():
    assert github_slug("Choose your path") == "choose-your-path"
    assert github_slug("§13. The serving tier") == "13-the-serving-tier"
    assert github_slug("`engine.release` / synthesize") == \
        "enginerelease--synthesize"


def test_no_broken_links_in_readme_and_docs():
    files = ([os.path.join(REPO, "README.md")]
             + sorted(glob.glob(os.path.join(REPO, "docs", "*.md"))))
    assert files, "README.md not found?"
    errors = []
    for f in files:
        errors.extend(check_file(f, REPO))
    assert not errors, "\n".join(errors)


def test_checker_catches_broken_link(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](no_such_file.md) and "
                   "[noanchor](bad.md#nope)\n# Real Heading\n")
    errors = check_file(str(bad), str(tmp_path))
    assert len(errors) == 2
    assert "broken link target" in errors[0]
    assert "missing anchor" in errors[1]


def test_checker_skips_external_and_code_fences(tmp_path):
    ok = tmp_path / "ok.md"
    ok.write_text("[web](https://example.com)\n"
                  "```\n[fake](never_checked.md)\n```\n"
                  "[self](#real-heading)\n# Real Heading\n")
    assert check_file(str(ok), str(tmp_path)) == []


def test_checker_catches_dangling_section_refs(tmp_path):
    a = tmp_path / "a.md"
    a.write_text("## §1 One\n\nsee §2 and [b.md §9](b.md) and b.md §1\n")
    (tmp_path / "b.md").write_text("## §1 Only\n")
    errors = check_file(str(a), str(tmp_path))
    assert len(errors) == 2
    assert "dangling same-file reference §2" in errors[0]
    assert "b.md §9" in errors[1]


def test_section_refs_skip_fences_and_unnumbered_files(tmp_path):
    ok = tmp_path / "ok.md"
    ok.write_text("# No section numbers here\n\n§99 is fine: this file has "
                  "no § headings\n```\nDESIGN.md §42 never checked\n```\n")
    assert check_file(str(ok), str(tmp_path)) == []


def test_main_exit_codes(tmp_path, capsys):
    good = tmp_path / "g.md"
    good.write_text("# Hi\n")
    assert main([str(good)]) == 0
    bad = tmp_path / "b.md"
    bad.write_text("[x](gone.md)\n")
    assert main([str(bad)]) == 1
    assert "broken link target" in capsys.readouterr().err
