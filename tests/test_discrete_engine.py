"""DiscreteEngine (docs/DESIGN.md §10): secure release at fused-engine tier —
zero-noise exactness, big-γ² completion, exactness-boundary tiers, the
no-per-clique-kron_matvec_np hot-path contract, and the sharded/corpus wiring."""
import random
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.core import (Domain, MarginalWorkload, PrivacyBudget, all_kway,
                        select_sum_of_variances)
from repro.core.discrete import (DiscreteMeasurement, clique_gamma2,
                                 discrete_pcost_of_plan, discrete_zcdp_rho,
                                 measure_discrete, naive_discrete_rho)
from repro.core.mechanism import exact_marginals_from_x, pcost_of_plan
from repro.engine import DiscreteEngine, corpus_marginal_release
from repro.engine.sharded import sharded_measure


def _small_plan(pcost=1.0):
    dom = Domain.create([4, 3, 2])
    wk = all_kway(dom, 2, include_lower=True)
    return dom, wk, select_sum_of_variances(wk, pcost)


_ZERO = lambda g2, n, r: np.zeros(n, dtype=object)  # noqa: E731


def test_engine_via_plan_protocol():
    _dom, _wk, plan = _small_plan()
    eng = plan.engine(secure=True)
    assert isinstance(eng, DiscreteEngine)
    assert eng.stats.measure_signatures > 0
    assert len(eng.chain_plans()) > 0          # H/Y†/U chains registered


def test_zero_noise_reconstructs_exactly(rng):
    dom, wk, plan = _small_plan()
    x = rng.integers(0, 50, dom.universe_size()).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    eng = plan.engine(secure=True)
    meas = eng.measure(margs, jax.random.PRNGKey(0), _noise_override=_ZERO)
    tables = eng.reconstruct(meas)
    for c in wk.cliques:
        assert np.allclose(tables[c], margs[c], atol=1e-4), c


def test_matches_measure_discrete_parameters(rng):
    """σ̄/γ² (the privacy-relevant quantities) agree exactly with the
    host-exact reference measure_discrete."""
    dom, _wk, plan = _small_plan()
    x = rng.integers(0, 30, dom.universe_size()).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    eng = plan.engine(secure=True)
    em = eng.measure(margs, jax.random.PRNGKey(0))
    dm = measure_discrete(plan, margs, random.Random(0))
    for c in plan.cliques:
        assert isinstance(em[c], DiscreteMeasurement)
        assert em[c].sigma_bar == dm[c].sigma_bar
        assert em[c].gamma2 == dm[c].gamma2
        assert em[c].omega.shape == dm[c].omega.shape


def test_zero_noise_matches_oracle_transforms(rng):
    """Engine H/Y† (device or exact tier) ≈ the float64 kron_matvec_np oracle."""
    dom, _wk, plan = _small_plan()
    x = rng.integers(0, 40, dom.universe_size()).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    eng = plan.engine(secure=True)
    em = eng.measure(margs, jax.random.PRNGKey(0), _noise_override=_ZERO)
    dm = measure_discrete(plan, margs, random.Random(0), _noise_override=_ZERO)
    for c in plan.cliques:
        assert np.allclose(em[c].omega, dm[c].omega, atol=1e-4), c


def test_seed_determinism(rng):
    dom, _wk, plan = _small_plan()
    x = rng.integers(0, 30, dom.universe_size()).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    eng = plan.engine(secure=True)
    m1 = eng.measure(margs, jax.random.PRNGKey(11))
    m2 = eng.measure(margs, jax.random.PRNGKey(11))
    m3 = eng.measure(margs, jax.random.PRNGKey(12))
    assert all(np.array_equal(m1[c].omega, m2[c].omega) for c in plan.cliques)
    assert any(not np.array_equal(m1[c].omega, m3[c].omega)
               for c in plan.cliques)


def test_no_per_clique_kron_matvec_np_on_hot_path(rng, monkeypatch):
    """The secure hot path never touches the per-clique host oracle."""
    import repro.core.kron as kron
    src = Path(__file__).resolve().parents[1] / "src/repro/engine/discrete_engine.py"
    assert "kron_matvec_np(" not in src.read_text()   # no call sites
    dom, _wk, plan = _small_plan()
    x = rng.integers(0, 30, dom.universe_size()).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    eng = plan.engine(secure=True)

    def _boom(*a, **k):
        raise AssertionError("kron_matvec_np called on the secure hot path")
    monkeypatch.setattr(kron, "kron_matvec_np", _boom)
    meas = eng.measure(margs, jax.random.PRNGKey(0))
    assert len(meas) == len(plan.cliques)


def test_big_gamma2_completes():
    """γ² at (Πn_i = 10²⁰)² scale (σ̄² = 1e34 on a 10³-cell clique): the
    seed-era float-sqrt path overflowed; the integer path completes."""
    dom = Domain.create([10, 10, 10])
    wk = MarginalWorkload(dom, ((0, 1, 2),))
    plan = select_sum_of_variances(wk, 1.0)
    plan.sigma[plan.table.index[(0, 1, 2)]] = 1e34
    _sb, gamma2, _np = clique_gamma2(plan, (0, 1, 2))
    assert gamma2 >= 10 ** 40                  # Πn_i = 10²⁰ scale
    x = np.random.default_rng(0).integers(0, 100, 1000).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    eng = plan.engine(secure=True)
    meas = eng.measure(margs, jax.random.PRNGKey(0))
    for m in meas.values():
        assert np.all(np.isfinite(m.omega))
    # same through the host-exact reference (batched sampler default)
    dm = measure_discrete(plan, margs, random.Random(0))
    assert all(np.all(np.isfinite(m.omega)) for m in dm.values())


def test_sliver_sigma_beyond_float_range_completes():
    """σ̄² ~ 1e300 slivers: γ² = σ̄²·Πn_i² leaves float64 range entirely —
    ``float(gamma2)`` overflows — yet measurement completes finite."""
    dom = Domain.create([10, 10, 10])
    wk = MarginalWorkload(dom, ((0, 1, 2),))
    plan = select_sum_of_variances(wk, 1.0)
    plan.sigma[plan.table.index[(0, 1, 2)]] = 1e304   # σ̄²·Πn_i² = 1e310
    _sb, gamma2, _ = clique_gamma2(plan, (0, 1, 2))
    with pytest.raises(OverflowError):
        float(gamma2)                           # the seed-era crash site
    x = np.random.default_rng(0).integers(0, 50, 1000).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    meas = plan.engine(secure=True).measure(margs, jax.random.PRNGKey(0))
    assert all(np.all(np.isfinite(m.omega)) for m in meas.values())


def test_exact_h_tier_engages_on_large_counts():
    """Counts beyond the chain dtype's exact-integer range route H to the
    exact integer tier — and stay exact (zero-noise equality vs oracle)."""
    dom = Domain.create([10, 10, 10])
    wk = MarginalWorkload(dom, ((0, 1, 2),))
    plan = select_sum_of_variances(wk, 1.0)
    x = np.random.default_rng(2).integers(0, 10 ** 6, 1000).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    eng = plan.engine(secure=True)
    em = eng.measure(margs, jax.random.PRNGKey(0), _noise_override=_ZERO)
    assert eng.stats.exact_h_groups > 0
    dm = measure_discrete(plan, margs, random.Random(0), _noise_override=_ZERO)
    for c in plan.cliques:
        # float64 oracle vs exact-int H + device Y†: agreement to Y† precision
        scale = max(1.0, np.abs(dm[c].omega).max())
        assert np.allclose(em[c].omega, dm[c].omega, atol=1e-4 * scale), c


def test_fused_kernel_path_matches(rng):
    """use_kernel=True (fused Pallas chains, interpret mode on CPU) agrees
    with the batched-jnp path: same noise stream, same integers."""
    dom, _wk, plan = _small_plan()
    x = rng.integers(0, 30, dom.universe_size()).astype(float)
    margs = exact_marginals_from_x(dom, plan.cliques, x)
    eng_jnp = DiscreteEngine(plan, use_kernel=False)
    eng_ker = DiscreteEngine(plan, use_kernel=True)
    assert eng_ker.stats.compile_warmups > 0
    m_jnp = eng_jnp.measure(margs, jax.random.PRNGKey(5))
    m_ker = eng_ker.measure(margs, jax.random.PRNGKey(5))
    for c in plan.cliques:
        assert np.allclose(m_jnp[c].omega, m_ker[c].omega, atol=1e-4), c


def test_sharded_measure_secure(rng):
    from repro.data.tabular import synthetic_records
    dom, _wk, plan = _small_plan()
    records = synthetic_records(dom, 2000, seed=0)
    meas = sharded_measure(plan, records, jax.random.PRNGKey(3), secure=True)
    assert set(meas) == set(plan.cliques)
    assert all(isinstance(m, DiscreteMeasurement) for m in meas.values())
    # engine cache: repeated calls reuse one engine and stay deterministic
    meas2 = sharded_measure(plan, records, jax.random.PRNGKey(3), secure=True)
    assert all(np.array_equal(meas[c].omega, meas2[c].omega)
               for c in plan.cliques)


def test_engine_cache_keys_on_digits():
    """Regression: σ̄/γ² are baked into a secure engine at construction, so
    the sharded engine cache must never hand a digits=4 engine to a
    digits=6 caller (noise served would disagree with privacy charged)."""
    from repro.engine.sharded import _engine_for
    import jax.numpy as jnp
    _dom, _wk, plan = _small_plan()
    e4 = _engine_for(plan, False, jnp.float32, secure=True, digits=4)
    e6 = _engine_for(plan, False, jnp.float32, secure=True, digits=6)
    assert e4 is not e6
    assert e4.digits == 4 and e6.digits == 6
    c = plan.cliques[-1]
    assert e6.sigma_bars[c] <= e4.sigma_bars[c]   # finer rounding-up
    assert _engine_for(plan, False, jnp.float32, secure=True, digits=4) is e4


def test_corpus_release_secure(rng):
    from repro.data.tabular import synthetic_records
    dom = Domain.create([4, 3, 2])
    wk = all_kway(dom, 2, include_lower=True)
    records = synthetic_records(dom, 3000, seed=1)
    budget = PrivacyBudget.from_zcdp(2.0)
    tables, variances, report = corpus_marginal_release(
        dom, wk, records, budget, 1.0, jax.random.PRNGKey(1), secure=True)
    assert set(tables) == set(wk.cliques)
    # exact discrete pcost is charged, never more than the continuous pcost
    plan = select_sum_of_variances(wk, 1.0)
    assert report["pcost_spent"] <= pcost_of_plan(plan) + 1e-9
    assert report["pcost_spent"] == pytest.approx(discrete_pcost_of_plan(plan))


def test_plus_plan_rejects_secure():
    from repro.core.plus import PlusSchema, select_plus
    dom = Domain.create([8, 5], kinds=["numeric", "categorical"])
    wk = all_kway(dom, 2, include_lower=True)
    schema = PlusSchema.create(dom, ["range", "identity"])
    plan = select_plus(wk, schema, pcost_budget=1.0)
    with pytest.raises(ValueError):
        plan.engine(secure=True)
    with pytest.raises(ValueError):
        sharded_measure(plan, np.zeros((4, 2), np.int32),
                        jax.random.PRNGKey(0), secure=True)


def test_naive_rho_dominates_discrete_rho():
    """Satellite: naive_discrete_rho (rationalized σ̄) ≥ Σ discrete ρ_A —
    Example 2's blow-up never inverts once both sides use the same σ̄."""
    for sizes in ([2, 2, 2], [4, 3, 2]):
        dom = Domain.create(sizes)
        wk = all_kway(dom, len(sizes), include_lower=True)
        plan = select_sum_of_variances(wk, 1.0)
        alg3 = sum(discrete_zcdp_rho(
            dom, c, clique_gamma2(plan, c)[0]) for c in plan.cliques)
        assert naive_discrete_rho(plan) >= float(alg3)


def test_discrete_pcost_never_exceeds_continuous():
    _dom, _wk, plan = _small_plan(pcost=0.7)
    assert discrete_pcost_of_plan(plan) <= pcost_of_plan(plan) + 1e-12
    eng = plan.engine(secure=True)
    assert eng.pcost() == pytest.approx(discrete_pcost_of_plan(plan))
    assert eng.rho() == pytest.approx(discrete_pcost_of_plan(plan) / 2.0)
