"""Observability subsystem: metrics primitives, exposition, tracing.

Covers the registry/family/child layer (atomicity under threads, Prometheus
text rendering + the round-trip parser), the tracer's zero-cost-off and
span-tree semantics, and the registry-backed rewiring of the legacy stats
objects (EngineStats, ChainStats) that the engines and kernels mutate from
worker threads.
"""
import json
import threading

import pytest

from repro.obs import (NOOP_SPAN, AtomicCounter, MetricsRegistry, Tracer,
                       exposition, parse_exposition)
from repro.obs.naming import chain_label


# ------------------------------------------------------------- primitives
def test_atomic_counter_threaded():
    c = AtomicCounter()
    n_threads, per = 8, 2500

    def work():
        for _ in range(per):
            c.inc()

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * per


def test_atomic_counter_set_max():
    c = AtomicCounter()
    c.set_max(5)
    c.set_max(3)
    assert c.value == 5


def test_counter_family_labels_and_render():
    reg = MetricsRegistry()
    fam = reg.counter("x_total", "things", labels=("kind",))
    fam.labels(kind="a").inc()
    fam.labels(kind="a").inc(2)
    fam.labels(kind="b").inc()
    text = fam.render()
    assert "# TYPE x_total counter" in text
    assert 'x_total{kind="a"} 3' in text
    assert 'x_total{kind="b"} 1' in text
    # same label value -> same child object (get-or-create)
    assert fam.labels(kind="a") is fam.labels(kind="a")


def test_unlabeled_family_proxies_implicit_child():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(4)
    g.set_max(2)
    assert g.value == 4
    labeled = reg.counter("y_total", labels=("t",))
    with pytest.raises(ValueError, match="use .labels"):
        labeled.inc()


def test_histogram_cumulative_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", labels=(), buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    text = h.render()
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 3' in text        # cumulative
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_count 4" in text
    snap = h.labels().snapshot()
    assert snap["count"] == 4
    assert snap["sum"] == pytest.approx(6.05)


def test_summary_ring_bounded_and_quantiles():
    reg = MetricsRegistry()
    s = reg.summary("ring_seconds", maxlen=10).labels()
    for i in range(100):
        s.observe(float(i))
    assert len(s.samples()) == 10                         # bounded ring
    assert s.samples() == [float(i) for i in range(90, 100)]
    assert s.count == 100                                 # lifetime count
    assert s.quantile(0.5) in (94.0, 95.0)   # nearest-rank over the ring
    assert s.quantile(0.99) == 99.0


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("z_total", labels=("t",))
    assert reg.counter("z_total", labels=("t",)) is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("z_total", labels=("t",))
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("z_total", labels=("other",))


def test_exposition_merge_dedups_family_names():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("dup_total").inc(1)
    b.counter("dup_total").inc(99)
    b.counter("only_b_total").inc(7)
    text = exposition(a, b)
    parsed = parse_exposition(text)
    assert parsed["dup_total"][""] == 1                   # first registry wins
    assert parsed["only_b_total"][""] == 7
    assert text.count("# TYPE dup_total") == 1


def test_parse_exposition_roundtrip_with_labels():
    reg = MetricsRegistry()
    reg.counter("r_total", "help text", labels=("tenant",)).labels(
        tenant="t 0").inc(3)
    reg.gauge("g").set(2.5)
    parsed = parse_exposition(reg.exposition())
    assert parsed["r_total"]['tenant="t 0"'] == 3
    assert parsed["g"][""] == 2.5
    with pytest.raises(ValueError, match="malformed"):
        parse_exposition("no_value_here")


def test_sample_value_convenience():
    reg = MetricsRegistry()
    reg.counter("s_total", labels=("k",)).labels(k="x").inc(4)
    assert reg.sample_value("s_total", k="x") == 4
    assert reg.sample_value("s_total", k="missing") is None
    assert reg.sample_value("never_registered") is None


# ---------------------------------------------------------------- tracing
def test_tracer_off_returns_falsy_noop_singleton():
    tr = Tracer()
    sp = tr.span("anything")
    assert sp is NOOP_SPAN and not sp
    assert sp.set(a=1) is sp                # chainable, allocation-free
    with sp:
        pass
    sp.end()                                # idempotent no-op


def test_span_tree_parents_via_context():
    tr = Tracer()
    tr.enable()                             # ring only, no file
    try:
        with tr.span("root") as root, tr.span("child") as child:
            with tr.span("grandchild") as gc:
                pass
        spans = {s.name: s for s in tr.drain()}
        assert spans["child"].parent_id == root.span_id
        assert spans["grandchild"].parent_id == child.span_id
        assert ({s.trace_id for s in spans.values()} == {root.trace_id})
        assert gc.t1 >= gc.t0
    finally:
        tr.disable()


def test_span_explicit_parent_t0_and_error_attrs():
    tr = Tracer()
    tr.enable()
    try:
        root = tr.span("root")
        late = tr.span("backdated", parent=root, t0=root.t0 - 1.0)
        late.end()
        assert late.parent_id == root.span_id
        assert late.trace_id == root.trace_id
        assert late.to_dict()["dur_us"] >= 1e6
        with pytest.raises(RuntimeError, match="boom"), tr.span("failing"):
            raise RuntimeError("boom")
        root.end()
        by_name = {s.name: s for s in tr.drain()}
        assert by_name["failing"].attrs["error"] == "RuntimeError"
        assert "boom" in by_name["failing"].attrs["error_msg"]
    finally:
        tr.disable()


def test_tracer_activate_crosses_thread_boundary():
    tr = Tracer()
    tr.enable()
    try:
        root = tr.span("root")
        child_ids = {}

        def worker():
            with tr.activate(root), tr.span("in_thread") as sp:
                child_ids["parent"] = sp.parent_id
                child_ids["trace"] = sp.trace_id

        t = threading.Thread(target=worker)
        t.start()
        t.join()
        root.end()
        assert child_ids["parent"] == root.span_id
        assert child_ids["trace"] == root.trace_id
    finally:
        tr.disable()


def test_tracer_jsonl_sink_and_file_cap(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    tr = Tracer()
    tr.enable(path, max_file_spans=3)
    try:
        for i in range(5):
            tr.span("s").set(i=i).end()
        tr.flush()
        lines = [json.loads(ln) for ln in
                 (tmp_path / "trace.jsonl").read_text().splitlines()]
        assert len(lines) == 3                            # file cap enforced
        assert all(set(rec) >= {"trace", "span", "name", "t0", "t1",
                                "dur_us", "attrs"} for rec in lines)
        st = tr.stats()
        assert st["written"] == 3 and st["dropped"] == 2
        assert len(tr.drain()) == 5                       # ring kept them all
    finally:
        tr.disable()


# ------------------------------------------------- rewired legacy stores
def test_engine_stats_threaded_bumps_are_atomic():
    from repro.engine.engine import EngineStats
    st = EngineStats()
    n_threads, per = 8, 1000

    def work():
        for _ in range(per):
            st.bump("measure_calls")

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert st.measure_calls == n_threads * per
    # legacy field surface still writable (tests/seeds do this)
    st.measure_calls = 2
    assert st.measure_calls == 2
    assert st.to_dict()["measure_calls"] == 2


def test_engine_stats_mirrors_global_registry():
    from repro.engine.engine import EngineStats
    from repro.obs import REGISTRY
    before = REGISTRY.sample_value("repro_engine_events_total",
                                   counter="synthesize_calls") or 0
    EngineStats().bump("synthesize_calls", 3)
    after = REGISTRY.sample_value("repro_engine_events_total",
                                  counter="synthesize_calls")
    assert after == before + 3


def test_chain_stats_reset_window_vs_monotone_mirror():
    from repro.kernels.kron_matvec.stats import (CHAIN_STATS,
                                                 chain_stats,
                                                 reset_chain_stats)
    from repro.obs import REGISTRY
    reset_chain_stats()
    before = REGISTRY.sample_value("repro_kernel_events_total",
                                   event="pads") or 0
    CHAIN_STATS.inc("pads", 2)
    assert chain_stats()["pads"] == 2
    reset_chain_stats()
    assert chain_stats()["pads"] == 0                     # window resets
    mirrored = REGISTRY.sample_value("repro_kernel_events_total", event="pads")
    assert mirrored == before + 2                         # mirror is monotone


def test_chain_label_format():
    assert chain_label((5, 5, 5), 16, "float32") == "5x5x5/b16/f32"
    assert chain_label((), 4) == "scalar/b4/f32"
    assert chain_label((7,), 2, "bfloat16") == "7/b2/bf16"
